"""Telemetry bus: the adaptive runtime's low-overhead observation plane.

Every ``repro.db.Session`` owns a ``TelemetryBus`` and feeds it once per
flush: per-op-class dispatch latency spans (apply / query / rank /
compact), ``query.STAGE_COUNTERS`` snapshots, periodic ``LiveStats`` /
``ShardedStats`` rollups (chain depth, fill factor, per-shard live
counts), and — on the sharded tier — the per-shard key-touch histogram
the skew monitor reasons about.  ``runtime.ft``'s ``Heartbeat`` and
``StragglerMonitor`` report into the same bus when handed one, so the
serving control loops (``tuning.admission``, ``tuning.autotune``) read
ONE surface instead of scraping N subsystems.

Design constraints, in order:

  1. *Low overhead.*  A span record is two numpy scalar writes into a
     preallocated ring — no allocation, no locks on the hot path (the
     session is single-threaded by contract; background reporters like
     the heartbeat only append to their own event ring).  The perf CI
     gate holds the ``batched_lookup`` suite to the ``compare.py``
     threshold with telemetry always on.
  2. *Bounded memory.*  Everything is ring-buffered: old observations
     fall off instead of growing without bound, which also makes the
     quantile summaries *windowed* — exactly what an online controller
     wants (traffic from an hour ago should not drag today's p99).
  3. *Machine readable.*  ``export()`` returns one JSON-able dict —
     quantile summaries per op class, gauges, counters, recent events —
     consumed by ``benchmarks/run.py --scenario`` (stamped alongside
     ``_meta``) and by tests pinning controller behavior.

Span rings are keyed by ``(op, tag)``: the session tags ``query`` spans
with the serving backend name, so the autotuner can compare measured
per-backend latency for the same plan shape without a join.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

DEFAULT_CAPACITY = 512

# The quantiles every summary reports (the SLO controller keys on p99).
QUANTILES = (50.0, 95.0, 99.0)


class _Ring:
    """Fixed-capacity ring of float64 observations (seconds)."""

    __slots__ = ("buf", "idx", "count")

    def __init__(self, capacity: int):
        self.buf = np.zeros(capacity, np.float64)
        self.idx = 0
        self.count = 0

    def push(self, value: float) -> None:
        self.buf[self.idx] = value
        self.idx = (self.idx + 1) % len(self.buf)
        self.count += 1

    def window(self) -> np.ndarray:
        """The filled window, oldest-first not guaranteed (quantiles are
        order-free)."""
        n = min(self.count, len(self.buf))
        return self.buf[:n]

    def quantiles(self) -> Dict[str, float]:
        w = self.window()
        if not len(w):
            return {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0,
                    "mean": 0.0}
        qs = np.percentile(w, QUANTILES)
        return {"n": int(self.count), "p50": float(qs[0]),
                "p95": float(qs[1]), "p99": float(qs[2]),
                "mean": float(w.mean())}


class TouchTracker:
    """EWMA per-shard key-touch histogram (the load axis of skew).

    ``ShardedLiveStore`` owns one and bumps it on every routed read and
    write batch; the decayed rates answer "which shard is HOT", which the
    live-count histogram cannot (a balanced-size store can still serve
    99% of its traffic from one shard).  ``imbalance`` mirrors the
    size-based ``ShardedStats.imbalance`` contract: max shard rate over
    the balanced mean, 1.0 = perfectly balanced, 0.0 = no data yet.
    """

    def __init__(self, num_shards: int, decay: float = 0.95):
        self.decay = float(decay)
        self.rates = np.zeros(num_shards, np.float64)
        self.total_events = 0

    def record(self, shard_counts: np.ndarray) -> None:
        """Fold one batch's per-shard touch counts into the EWMA."""
        self.rates *= self.decay
        self.rates += shard_counts
        self.total_events += int(np.asarray(shard_counts).sum())

    def reset(self) -> None:
        """Forget the window (called after a migration/rebalance so the
        monitor re-observes the NEW placement instead of ping-ponging on
        stale heat)."""
        self.rates[:] = 0.0
        self.total_events = 0

    @property
    def imbalance(self) -> float:
        total = float(self.rates.sum())
        if total <= 0.0:
            return 0.0
        mean = total / len(self.rates)
        return float(self.rates.max()) / mean

    def snapshot(self) -> Tuple[float, ...]:
        return tuple(float(r) for r in self.rates)


class TelemetryBus:
    """Ring-buffered event stream + quantile summaries (module doc).

    Hot-path API (called per flush by the session):

        bus.span("apply", seconds, n=items)        # latency observation
        bus.span("query", seconds, n=lanes, tag=backend_name)
        bus.counters(query.STAGE_COUNTERS)         # snapshot deltas
        bus.gauge("max_chain", stats.max_chain)    # last-value gauges
        bus.touch(per_shard_counts)                # sharded tier only

    Read API (controllers, tests, exports):

        bus.quantiles("query")          # {'n', 'p50', 'p95', 'p99', ...}
        bus.p99("apply")                # scalar convenience
        bus.rate("apply")               # mean seconds-per-item
        bus.by_tag("query")             # {backend: summary}
        bus.export() / bus.export_json(path)
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 event_capacity: int = 256):
        self.capacity = int(capacity)
        self._spans: Dict[Tuple[str, Optional[str]], _Ring] = {}
        # Per-(op, tag) seconds-per-item rings: the admission
        # controller's cost model (predicted flush time scales with the
        # queue, not just with history's batch sizes).
        self._unit: Dict[Tuple[str, Optional[str]], _Ring] = {}
        self._gauges: Dict[str, float] = {}
        self._counters: Dict[str, int] = {}
        self._stage_base: Optional[Dict[str, int]] = None
        self._events: List[dict] = []
        self._event_capacity = int(event_capacity)
        self._event_lock = threading.Lock()   # background reporters only
        self.touch_rates: Tuple[float, ...] = ()
        self.n_flushes = 0

    # -- hot path -------------------------------------------------------------

    def span(self, op: str, seconds: float, *, n: int = 0,
             tag: Optional[str] = None) -> None:
        """Record one dispatch latency span for op class ``op``.

        ``n`` is the item count the span served (queue items, plan
        lanes); ``tag`` buckets the observation (the session tags query
        spans with the backend that ranked them).  Tagged spans are ALSO
        folded into the untagged ring so op-class summaries see every
        observation.
        """
        for key in ({(op, None), (op, tag)} if tag is not None
                    else {(op, None)}):
            ring = self._spans.get(key)
            if ring is None:
                ring = self._spans[key] = _Ring(self.capacity)
            ring.push(seconds)
            if n > 0:
                unit = self._unit.get(key)
                if unit is None:
                    unit = self._unit[key] = _Ring(self.capacity)
                unit.push(seconds / n)

    def counters(self, stage_counters: Dict[str, int]) -> None:
        """Fold a ``query.STAGE_COUNTERS`` snapshot into the bus as
        monotonic totals (the first snapshot is the baseline, so the bus
        reports counts SINCE the session opened, not process lifetime)."""
        if self._stage_base is None:
            self._stage_base = dict(stage_counters)
        for k, v in stage_counters.items():
            self._counters[f"stage_{k}"] = v - self._stage_base.get(k, 0)

    def bump(self, name: str, inc: int = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def touch(self, rates) -> None:
        """Publish the sharded tier's per-shard touch-rate histogram."""
        self.touch_rates = tuple(float(r) for r in rates)

    def event(self, kind: str, **fields) -> None:
        """Append one discrete event (heartbeat, straggler, autotuner
        action) to the bounded event ring.  Thread-safe: heartbeat
        threads report here concurrently with the session."""
        rec = {"kind": kind, "time": time.time(), **fields}
        with self._event_lock:
            self._events.append(rec)
            if len(self._events) > self._event_capacity:
                del self._events[:len(self._events) - self._event_capacity]

    def flush_mark(self) -> None:
        self.n_flushes += 1

    # -- read side ------------------------------------------------------------

    def quantiles(self, op: str, tag: Optional[str] = None) -> Dict[str, float]:
        ring = self._spans.get((op, tag))
        if ring is None:
            return {"n": 0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
        return ring.quantiles()

    def p99(self, op: str, tag: Optional[str] = None) -> float:
        return self.quantiles(op, tag)["p99"]

    def rate(self, op: str, tag: Optional[str] = None) -> float:
        """Mean measured seconds-per-item for ``op`` (0.0 = no data)."""
        ring = self._unit.get((op, tag))
        if ring is None or not ring.count:
            return 0.0
        return float(ring.window().mean())

    def by_tag(self, op: str) -> Dict[str, Dict[str, float]]:
        """Per-tag summaries of one op class — the autotuner's
        measured-latency table ({backend_name: quantile summary})."""
        return {tag: ring.quantiles()
                for (o, tag), ring in self._spans.items()
                if o == op and tag is not None}

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def gauges(self) -> Dict[str, float]:
        return dict(self._gauges)

    def events(self, kind: Optional[str] = None) -> List[dict]:
        with self._event_lock:
            evs = list(self._events)
        return [e for e in evs if kind is None or e["kind"] == kind]

    # -- export ---------------------------------------------------------------

    def export(self) -> dict:
        """One JSON-able snapshot of everything the bus holds.

        Schema (docs/ARCHITECTURE.md "Adaptive runtime"):

            {"flushes": int,
             "spans":   {"op" | "op:tag": {n, p50, p95, p99, mean}},
             "rates":   {"op" | "op:tag": seconds_per_item},
             "gauges":  {name: value},
             "counters": {name: int},      # incl. stage_* deltas
             "touch_rates": [per-shard EWMA...],
             "events":  [{kind, time, ...} ...]}
        """
        def keyname(op, tag):
            return op if tag is None else f"{op}:{tag}"

        return {
            "flushes": self.n_flushes,
            "spans": {keyname(o, t): r.quantiles()
                      for (o, t), r in self._spans.items()},
            "rates": {keyname(o, t): float(r.window().mean())
                      for (o, t), r in self._unit.items() if r.count},
            "gauges": self.gauges(),
            "counters": dict(self._counters),
            "touch_rates": list(self.touch_rates),
            "events": self.events(),
        }

    def export_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.export(), fh, indent=2, sort_keys=True)
