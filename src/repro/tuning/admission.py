"""Deadline-based flush admission + bounded-queue backpressure.

The session's historical flush discipline is *pull*: requests queue until
a caller flushes (or touches a ``Ticket.result()``).  Under hostile
traffic that lets tail latency grow without bound — a flood of
submissions piles onto one giant flush whose dispatch cost then blows
every deadline at once.  ``AdmissionController`` closes the loop with two
mechanisms, both driven by the telemetry bus's online estimates:

*Deadline flushing* (``IndexSpec(slo_ms=...)``): each submission arms a
deadline ``oldest_enqueue + slo``.  Before accepting the next
submission, the session asks ``should_flush(...)``, which compares the
remaining headroom against the PREDICTED cost of flushing what is
already queued — measured seconds-per-item EWMAs off the bus, padded by
the measured p99 fixed overhead — and fires the flush while it can still
finish inside the SLO, not after the violation is unavoidable.

*Backpressure* (``IndexSpec(max_pending=...)``): a full pending queue
sheds the NEXT submission with a typed ``repro.db.OverloadError``
carrying the queue depth and the estimated wait (predicted cost of
draining what is queued), so a caller can back off / retry-after instead
of silently inflating the tail.  Shedding happens BEFORE enqueue: an
admitted request is never dropped by this mechanism.

State machine (docs/ARCHITECTURE.md renders it)::

    IDLE --submit--> PENDING --deadline-would-pass--> FLUSH -> IDLE
                        |
                        +--queue full--> SHED (OverloadError; queue
                                         unchanged, caller retries)

With both knobs unset the controller is never constructed and the
session is bit-identical to the historical behavior (the dispatch
counter pin in tests/test_tuning.py holds it to that).
"""
from __future__ import annotations

import time
from typing import Optional, TYPE_CHECKING

if TYPE_CHECKING:  # import-cycle discipline: repro.db imports this package
    from .telemetry import TelemetryBus

# Cold-start flush-cost assumption (seconds/item) before the bus has any
# measurements: pessimistic enough that the first deadline decisions
# flush early rather than late.
COLD_START_RATE = 50e-6
# Headroom multiplier on the predicted cost: flush at deadline - margin *
# predicted instead of shaving it exact (the prediction is a tail
# estimate, not a bound).
SAFETY_MARGIN = 2.0


class AdmissionController:
    """Per-session deadline + backpressure state (see module doc).

    The session calls, in order, per submission:

        ctl.check_admit(session.pending)      # may raise OverloadError
        ...enqueue the ticket...
        ctl.note_submit(now)                  # arms the deadline
        if ctl.should_flush(now, session.pending): session.flush()

    and per flush: ``ctl.observe_flush(seconds, n_items)`` (feedback for
    the cost model) + ``ctl.on_flush()`` (disarms the deadline).
    """

    def __init__(self, bus: "TelemetryBus", *,
                 slo_ms: Optional[float] = None,
                 max_pending: Optional[int] = None):
        if slo_ms is not None and slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {slo_ms!r}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1, got {max_pending!r}")
        self.bus = bus
        self.slo_seconds = slo_ms / 1e3 if slo_ms is not None else None
        self.max_pending = max_pending
        self._oldest_enqueue: Optional[float] = None
        # EWMA cost model, fed by observe_flush: seconds-per-item slope
        # + fixed per-flush overhead (dispatch/compile floor).
        self._rate_ewma: Optional[float] = None
        self._fixed_ewma: float = 0.0
        self.deadline_flushes = 0      # flushes this controller forced
        self.shed = 0                  # submissions refused

    # -- backpressure ---------------------------------------------------------

    def check_admit(self, pending: int) -> None:
        """Refuse the next submission when the queue is full.

        Raises ``repro.db.OverloadError`` (lazy import — this package
        must stay importable without repro.db) with the current queue
        depth and the estimated wait to drain it.
        """
        if self.max_pending is None or pending < self.max_pending:
            return
        from repro.db.errors import OverloadError
        wait = self.predicted_flush_seconds(pending)
        self.shed += 1
        self.bus.bump("admission_shed")
        raise OverloadError(
            f"pending queue is full ({pending} >= "
            f"max_pending={self.max_pending}); flush or retry after "
            f"~{wait * 1e3:.2f} ms",
            queue_depth=pending, max_pending=self.max_pending,
            estimated_wait=wait)

    # -- deadline flushing ----------------------------------------------------

    def note_submit(self, now: Optional[float] = None) -> None:
        """Arm the deadline on the first submission of an empty queue."""
        if self._oldest_enqueue is None:
            self._oldest_enqueue = time.monotonic() if now is None else now

    def predicted_flush_seconds(self, pending: int) -> float:
        """Cost model: measured seconds-per-item slope x queue depth +
        measured fixed overhead.  Before any observation, a pessimistic
        cold-start rate (flushing too early is safe; too late is not)."""
        rate = self._rate_ewma
        if rate is None:
            rate = max(self.bus.rate("flush"), COLD_START_RATE)
        return self._fixed_ewma + rate * max(pending, 1)

    def deadline(self) -> Optional[float]:
        """Absolute monotonic deadline of the oldest pending request, or
        None when idle / no SLO configured."""
        if self.slo_seconds is None or self._oldest_enqueue is None:
            return None
        return self._oldest_enqueue + self.slo_seconds

    def should_flush(self, now: Optional[float] = None,
                     pending: int = 0) -> bool:
        """True when waiting any longer would let the oldest request's
        deadline pass before a flush started now could finish."""
        dl = self.deadline()
        if dl is None or pending == 0:
            return False
        now = time.monotonic() if now is None else now
        margin = SAFETY_MARGIN * self.predicted_flush_seconds(pending)
        if now + margin >= dl:
            self.deadline_flushes += 1
            self.bus.bump("admission_deadline_flush")
            return True
        return False

    # -- feedback -------------------------------------------------------------

    def observe_flush(self, seconds: float, n_items: int,
                      ewma: float = 0.8) -> None:
        """Fold one flush's measured wall time into the cost model.

        The slope EWMA tracks seconds-per-item; the fixed-overhead EWMA
        tracks the floor a 1-item flush pays (so tiny queues are not
        predicted to cost ~0).
        """
        if n_items <= 0:
            return
        rate = seconds / n_items
        self._rate_ewma = (rate if self._rate_ewma is None
                           else ewma * self._rate_ewma + (1 - ewma) * rate)
        if n_items == 1:
            self._fixed_ewma = (ewma * self._fixed_ewma
                                + (1 - ewma) * seconds)

    def on_flush(self) -> None:
        """Disarm the deadline: the queue was drained."""
        self._oldest_enqueue = None

    # -- introspection --------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able controller state (exported via Session.telemetry)."""
        return {"slo_ms": (self.slo_seconds * 1e3
                           if self.slo_seconds is not None else None),
                "max_pending": self.max_pending,
                "deadline_flushes": self.deadline_flushes,
                "shed": self.shed,
                "rate_ewma": self._rate_ewma,
                "fixed_ewma": self._fixed_ewma}
