"""Adaptive serving runtime: telemetry, admission control, autotuning.

Three cooperating pieces wired into ``repro.db.Session``:

    telemetry.TelemetryBus      ring-buffered per-flush observation plane
                                (latency spans, stage counters, gauges,
                                touch histograms, p50/p95/p99, JSON export)
    admission.AdmissionController
                                deadline-based flush admission
                                (IndexSpec slo_ms) + bounded-queue
                                backpressure (max_pending -> OverloadError)
    autotune.AutoTuner          measured-cost backend re-selection,
                                epoch-swap bucket retuning, and bounded
                                incremental shard migration under skew

Import-cycle discipline: nothing in this package imports ``repro.db`` at
module level (``repro.db`` imports us); the one db symbol we raise —
``OverloadError`` — lives in ``repro.db.errors`` and is imported lazily
at raise time.
"""
from .admission import AdmissionController
from .autotune import AutoTuner, prior_cost, prior_order
from .telemetry import TelemetryBus, TouchTracker

__all__ = ["AdmissionController", "AutoTuner", "TelemetryBus",
           "TouchTracker", "prior_cost", "prior_order"]
