"""Paged KV cache whose page table is a cgRX index session.

Serving with continuous batching is an insert/delete-heavy key->value
workload: logical cache blocks (seq_id, block_idx) map to physical pages
that are allocated as sequences grow and freed when they retire — exactly
the paper's Section 4 use case.  The page table here *is* the updatable
cgRX variant, served through the unified session API (``repro.db``,
tier='live' — the epoch snapshot + node-chain store):

    key    = seq_id << BLOCK_BITS | block_idx        (uint32/uint64)
    rowID  = physical page index

  * page allocation  -> table.insert(...)           (reps/BVH untouched)
  * sequence retire  -> table.delete(...)
  * decode gather    -> table.lookup(...)            (batched successor
                        search + chain post-filter via the rank engine)

Each paged call submits one batch and resolves it (auto-flush), so the
engine's tick-level batching (serving/engine.py coalesces ALL requests'
page-table traffic into one call per tick) maps to exactly one device
dispatch per op class per tick — the session's execution model.
Compaction is disabled (policy ``never()``): churn is the point, and the
paper's Fig. 15b property is that lookups do not degrade without
rebuilds.  All paged tables share one executable-cache scope, so every
cache in a process reuses the same compiled lookup pipelines.

The KV pages themselves are a (L, num_pages, page, KV, hd) pool; decode
gathers each sequence's pages by table lookup and attends over the
gathered window.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro import db
from repro.core.keys import KeyArray

BLOCK_BITS = 20   # up to 2^20 blocks per sequence
MAX_SEQS = 1 << 11

# One spec for every page table: updatable tier, no compaction (the
# accelerated structure must never rebuild under churn), shared compiled
# pipelines across caches.
_TABLE_SPEC_KW = dict(tier="live", bucket_size=16,
                      cache_scope="serving.paged")


def block_key(seq_id, block_idx):
    return (np.uint64(seq_id) << np.uint64(BLOCK_BITS)) | np.uint64(block_idx)


@dataclasses.dataclass
class PagedKVCache:
    """Physical page pool + cgRX page-table session."""

    k_pages: jnp.ndarray     # (L, P, page_size, KV, hd)
    v_pages: jnp.ndarray
    page_size: int
    num_pages: int
    table: db.Session        # cgRX updatable index: block key -> page id
    free_pages: List[int]
    seq_len: Dict[int, int]  # live sequences -> current length (host)

    @property
    def num_layers(self) -> int:
        return self.k_pages.shape[0]

    def close(self) -> None:
        """Release the page-table session (flushes pending tickets; part
        of the db lifecycle contract).  Idempotent; the engine calls it
        on teardown."""
        self.table.close()

    def __enter__(self) -> "PagedKVCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def create(num_layers: int, num_pages: int, page_size: int, kv_heads: int,
           head_dim: int, dtype=jnp.bfloat16, node_cap: int = 32
           ) -> PagedKVCache:
    shape = (num_layers, num_pages, page_size, kv_heads, head_dim)
    # Bootstrap table with a sentinel mapping so the structure is non-empty.
    boot = np.array([np.uint64((MAX_SEQS + 1) << BLOCK_BITS)])
    spec = db.IndexSpec(node_cap=node_cap,
                        policy=db.CompactionPolicy().never(),
                        **_TABLE_SPEC_KW)
    table = db.open(spec, boot, np.array([-1], np.int32))
    return PagedKVCache(
        k_pages=jnp.zeros(shape, dtype), v_pages=jnp.zeros(shape, dtype),
        page_size=page_size, num_pages=num_pages, table=table,
        free_pages=list(range(num_pages)), seq_len={})


# ---------------------------------------------------------------------------
# Table maintenance (host orchestration + device index updates).
# ---------------------------------------------------------------------------

def alloc_blocks(cache: PagedKVCache, seq_ids: List[int],
                 blocks: List[int]) -> Tuple[PagedKVCache, List[int]]:
    """Allocate physical pages for (seq, block) pairs; insert into table.

    Mutates ``cache`` in place (the table is a stateful session and
    ``free_pages`` is popped); the cache is also returned for call-site
    symmetry with the device-side ops, NOT as a fresh snapshot — the
    passed-in reference observes the allocation too.
    """
    assert len(cache.free_pages) >= len(seq_ids), "page pool exhausted"
    pages = [cache.free_pages.pop() for _ in seq_ids]
    keys = KeyArray.from_u64(np.array(
        [block_key(s, b) for s, b in zip(seq_ids, blocks)], dtype=np.uint64))
    rows = np.array(pages, dtype=np.int32)
    cache.table.insert(keys, rows).result()      # one apply dispatch
    return cache, pages


def free_sequence(cache: PagedKVCache, seq_id: int) -> PagedKVCache:
    """Retire a sequence: delete all its block keys, reclaim pages.

    Mutates ``cache`` in place (see ``alloc_blocks``): the returned
    cache IS the argument, not a pre-retirement snapshot.
    """
    length = cache.seq_len.pop(seq_id, 0)
    nblocks = -(-length // cache.page_size) if length else 0
    if nblocks == 0:
        return cache
    keys_np = np.array([block_key(seq_id, b) for b in range(nblocks)],
                       dtype=np.uint64)
    keys = KeyArray.from_u64(keys_np)
    # Look up pages before deleting so we can reclaim them.
    res = cache.table.lookup(keys).result()
    pages = np.asarray(res.row_id)
    found = np.asarray(res.found)
    cache.table.delete(keys).result()
    cache.free_pages.extend(int(p) for p, f in zip(pages, found) if f)
    return cache


def lookup_pages(cache: PagedKVCache, seq_ids: np.ndarray,
                 block_idx: np.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched (seq, block) -> physical page via the cgRX index."""
    keys_np = (seq_ids.astype(np.uint64) << np.uint64(BLOCK_BITS)) \
        | block_idx.astype(np.uint64)
    res = cache.table.lookup(KeyArray.from_u64(keys_np)).result()
    return res.row_id, res.found


# ---------------------------------------------------------------------------
# Device-side cache ops.
# ---------------------------------------------------------------------------

def write_token(cache: PagedKVCache, layer_kv: Tuple[jnp.ndarray, jnp.ndarray],
                page_ids: jnp.ndarray, slot_in_page: jnp.ndarray
                ) -> PagedKVCache:
    """Write one token's K/V for all layers.

    layer_kv: (k, v) each (L, B, KV, hd); page_ids/slot: (B,) int32.
    """
    k_new, v_new = layer_kv
    L, B = k_new.shape[0], k_new.shape[1]
    kp = cache.k_pages.at[:, page_ids, slot_in_page].set(
        k_new.transpose(0, 1, 2, 3))
    vp = cache.v_pages.at[:, page_ids, slot_in_page].set(v_new)
    return dataclasses.replace(cache, k_pages=kp, v_pages=vp)


def gather_window(cache: PagedKVCache, page_table_rows: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather each sequence's pages into a contiguous attention window.

    page_table_rows: (B, max_blocks) physical page ids (-1 padded).
    Returns k, v: (L, B, max_blocks * page_size, KV, hd); invalid pages
    read page 0 and must be masked by cache length in the attention.
    """
    safe = jnp.maximum(page_table_rows, 0)                    # (B, nb)
    k = cache.k_pages[:, safe]                                # (L,B,nb,ps,KV,hd)
    v = cache.v_pages[:, safe]
    L, B, nb, ps, KV, hd = k.shape
    return (k.reshape(L, B, nb * ps, KV, hd),
            v.reshape(L, B, nb * ps, KV, hd))
