"""Continuous-batching serving engine over the paged cgRX cache.

Request lifecycle: queued -> prefill (chunked full forward, KV written
into freshly allocated pages) -> decode (one token per engine tick for
every active sequence, pages gathered via the cgRX table) -> retired
(pages freed = index deletions).  Admission keeps the decode batch full
whenever the page pool allows — the standard continuous-batching loop,
here driving the paper's updatable index as its page table.

Index traffic is tick-batched: every decode tick issues ONE page-table
lookup and ONE paged KV write covering all active requests (and prefill
covers a whole prompt the same way), so probe work is amortized across
concurrent requests instead of dispatched per request — the same
query-level batching the rank engine (repro.query) applies to lookups.

This engine targets functional correctness + index-churn realism on CPU
with tiny configs (tests/examples); the dry-run serve path lowers the
dense-cache decode step (launch/dryrun.py) for the production shapes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm

from . import paged


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"       # queued | active | done


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    index_inserts: int = 0
    index_deletes: int = 0


class Engine:
    """Single-host reference engine (tiny configs)."""

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_seq: int = 256, page_size: int = 16,
                 num_pages: int = 512):
        assert cfg.family not in ("ssm", "hybrid"), \
            "paged engine serves attention caches; SSM state is O(1)"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.page_size = page_size
        self.kv_heads = cfg.num_kv_heads
        self.hd = cfg.hd
        self.cache = paged.create(cfg.num_layers, num_pages, page_size,
                                  cfg.num_kv_heads, cfg.hd)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.stats = EngineStats()
        self._next_seq = 0
        # Dense per-seq fallback caches for attention math (gathered from
        # pages each step); jitted once per shape.
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))

    # -- public API ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = self._next_seq
        self._next_seq += 1
        self.queue.append(Request(rid, prompt.astype(np.int32),
                                  max_new_tokens))
        return rid

    def step(self) -> None:
        """One engine tick: admit + prefill new requests, decode actives."""
        self._admit()
        self._decode_tick()
        self._retire()

    def run_to_completion(self, max_ticks: int = 10000) -> Dict[int, List[int]]:
        t = 0
        while (self.queue or self.active) and t < max_ticks:
            self.step()
            t += 1
        return {r.req_id: r.generated for r in self._done}

    def close(self) -> None:
        """Tear down the engine: close the paged cache's page-table
        session (flushes its pending tickets).  Idempotent."""
        self.cache.close()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- internals ------------------------------------------------------------

    _done: List[Request] = []

    def _admit(self) -> None:
        while self.queue and len(self.active) < self.max_batch:
            req = self.queue.pop(0)
            self._prefill(req)
            self.active[req.req_id] = req
            req.state = "active"

    def _pages_for(self, length: int) -> int:
        return -(-length // self.page_size)

    def _prefill(self, req: Request) -> None:
        """Run the prompt through decode steps (reference implementation
        favors simplicity; chunked prefill is the serve-path optimization
        measured in the dry-run).  Page-table traffic is batched: one
        index lookup + one paged write cover the entire prompt."""
        L = len(req.prompt)
        # allocate pages for the whole prompt + generation budget
        total = min(L + req.max_new_tokens, self.max_seq)
        nblocks = self._pages_for(total)
        self.cache, pages = paged.alloc_blocks(
            self.cache, [req.req_id] * nblocks, list(range(nblocks)))
        self.stats.index_inserts += nblocks
        self.cache.seq_len[req.req_id] = 0
        # per-request dense scratch cache for the model step
        req._dense = lm.init_decode_caches(self.cfg, 1, self.max_seq)
        for i, tok in enumerate(req.prompt):
            logits, req._dense = self._decode(
                self.params, req._dense,
                jnp.asarray([[int(tok)]], jnp.int32), jnp.int32(i))
        req._last_logits = logits
        self._mirror_to_pages([(req, pos) for pos in range(L)])
        self.cache.seq_len[req.req_id] = L
        self.stats.prefills += 1

    def _mirror_to_pages(self, reqs_pos) -> None:
        """Mirror freshly written dense KV into the paged pool through the
        cgRX table (the table lookup is the load-bearing part).

        ``reqs_pos``: list of (request, position) pairs.  The whole batch
        is served by ONE index lookup (a single successor-search launch
        over all (seq, block) keys) and ONE paged scatter — this is where
        the engine amortizes probe work across concurrent requests.
        """
        if not reqs_pos:
            return
        seqs = np.array([r.req_id for r, _ in reqs_pos])
        blks = np.array([pos // self.page_size for _, pos in reqs_pos])
        pages, found = paged.lookup_pages(self.cache, seqs, blks)
        assert bool(np.asarray(found).all()), "page table miss on own block"
        if not self.cache.k_pages.size:
            return
        ks, vs, slots, page_ids = [], [], [], []
        pages = np.asarray(pages)
        for (req, pos), page in zip(reqs_pos, pages):
            if req._dense.kv is None:
                continue
            kc, vc = req._dense.kv          # (L,1,S,KV,hd)
            ks.append(kc[:, 0, pos])
            vs.append(vc[:, 0, pos])
            slots.append(pos % self.page_size)
            page_ids.append(page)
        if not ks:
            return
        self.cache = paged.write_token(
            self.cache,
            (jnp.stack(ks, axis=1), jnp.stack(vs, axis=1)),   # (L,B,KV,hd)
            jnp.asarray(np.array(page_ids, np.int32)),
            jnp.asarray(np.array(slots, np.int32)))

    def _decode_tick(self) -> None:
        """One decode step for every active sequence.

        The per-request model steps run on independent dense caches, but
        all index traffic for the tick — page-table lookups and KV page
        writes — is coalesced into one batched call each (one device
        dispatch per tick, not one per request)."""
        stepped = []
        for req in list(self.active.values()):
            pos = self.cache.seq_len[req.req_id]
            if pos >= self.max_seq or len(req.generated) >= req.max_new_tokens:
                req.state = "done"
                continue
            last = req._last_logits
            tok = int(np.argmax(np.asarray(last[0, -1])))
            logits, req._dense = self._decode(
                self.params, req._dense,
                jnp.asarray([[tok]], jnp.int32), jnp.int32(pos))
            req._last_logits = logits
            req.generated.append(tok)
            stepped.append((req, pos))
            self.stats.decode_steps += 1
            self.stats.tokens_out += 1
        self._mirror_to_pages(stepped)
        for req, pos in stepped:
            self.cache.seq_len[req.req_id] = pos + 1

    def _retire(self) -> None:
        for rid, req in list(self.active.items()):
            if req.state == "done":
                nb = self._pages_for(self.cache.seq_len.get(rid, 0))
                self.cache = paged.free_sequence(self.cache, rid)
                self.stats.index_deletes += nb
                del self.active[rid]
                self._done.append(req)
