from . import engine, paged  # noqa: F401
