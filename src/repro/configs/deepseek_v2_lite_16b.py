"""DeepSeek-V2-Lite 16B: MLA (kv_lora=512) + MoE [arXiv:2405.04434].

Assignment note: the spec line says "MoE 64e top-6" while its comment says
"160 routed"; we follow the explicit field (64 routed experts, top-6,
2 shared), recorded in DESIGN.md.  The real model's dense first layer is
made MoE for scan homogeneity (noted deviation).
"""
from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
               v_head_dim=128),
)
