"""Architecture configuration schema + input shape cells.

Every assigned architecture is an ``ArchConfig``; the four LM shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeCell``s.
``input_specs`` builds jax.ShapeDtypeStruct stand-ins for the dry-run
(no allocation); ``tiny()`` produces the reduced same-family config used
by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_k: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    norm: str = "rms"              # rms | ln
    gated_mlp: bool = True
    act: str = "silu"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    attn_every: int = 0            # hybrid: shared attn after every k-th layer
    num_patches: int = 0           # vlm: vision-prefix length
    sub_quadratic: bool = False    # supports long_500k decode
    # training knobs
    remat: bool = True
    remat_policy: str = "full"     # full | dots | none  (§Perf knob)
    attn_probs_bf16: bool = False  # bf16 attention prob tiles (§Perf knob)
    loss_chunks: int = 8
    attn_block_q: int = 512
    attn_block_kv: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        repl: Dict = dict(
            num_layers=min(self.num_layers, 4 if self.attn_every == 0
                           else self.attn_every + 2),
            d_model=128,
            num_heads=max(min(self.num_heads, 4), 1),
            num_kv_heads=1 if self.num_kv_heads == 1
            else max(min(self.num_kv_heads, 2), 1),
            d_ff=256,
            vocab_size=512,
            head_dim=32 if self.head_dim else None,
            loss_chunks=2,
            attn_block_q=64, attn_block_kv=64,
        )
        if self.num_kv_heads == self.num_heads:   # keep MHA archs MHA
            repl["num_kv_heads"] = repl["num_heads"]
        if self.moe:
            repl["moe"] = MoECfg(num_experts=4,
                                 top_k=min(self.moe.top_k, 2),
                                 d_ff_expert=64,
                                 num_shared=min(self.moe.num_shared, 1))
        if self.mla:
            repl["mla"] = MLACfg(kv_lora_rank=32, qk_nope_dim=16,
                                 qk_rope_dim=8, v_head_dim=16)
            repl["head_dim"] = None
        if self.ssm:
            repl["ssm"] = SSMCfg(d_state=16, expand=2, head_dim=16,
                                 chunk=32)
        if self.num_patches:
            repl["num_patches"] = 8
        return dataclasses.replace(self, **repl)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (SSM/hybrid only here)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention family: 500k dense-softmax decode is "
                       "out of scope per assignment (see DESIGN.md)")
    return True, ""


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.num_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.num_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of S positions
    return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
