"""DBRX-132B: 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base]."""
from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    norm="ln", gated_mlp=True, act="silu", rope_theta=500000.0,
    moe=MoECfg(num_experts=16, top_k=4, d_ff_expert=10752),
)
