from .base import ArchConfig, MLACfg, MoECfg, SSMCfg, SHAPES, SHAPES_BY_NAME, ShapeCell, cell_applicable, input_specs  # noqa: F401
from .registry import ARCH_IDS, all_configs, get_config  # noqa: F401
