"""Zamba2-1.2B: Mamba2 backbone + shared attention block [arXiv:2411.15242].

Simplifications vs the released model (noted in DESIGN.md): one shared
attention+MLP block applied every 6 mamba layers (the release interleaves
two shared blocks with per-invocation LoRA); no embedding concat at shared
block input.
"""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm=SSMCfg(d_state=64, expand=2, head_dim=64),
    attn_every=6, sub_quadratic=True,
)
