"""StarCoder2-3B: dense GQA(kv=2), LayerNorm, non-gated GELU MLP
[arXiv:2402.19173]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    norm="ln", gated_mlp=False, act="gelu", qkv_bias=True,
    rope_theta=100000.0, norm_eps=1e-5,
)
