"""PaliGemma-3B: SigLIP + Gemma backbone [arXiv:2407.07726].

The SigLIP vision tower is a stub per assignment: input_specs() provides
precomputed patch embeddings (256 tokens at d_model) which the model
projects and prepends; attention over the prefix is causal (the release
uses full prefix attention — noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    act="gelu", num_patches=256,
)
