"""Registry of the 10 assigned architectures.  ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig

ARCH_IDS: List[str] = [
    "dbrx-132b",
    "deepseek-v2-lite-16b",
    "zamba2-1.2b",
    "qwen3-32b",
    "starcoder2-3b",
    "yi-6b",
    "qwen1.5-32b",
    "mamba2-370m",
    "musicgen-large",
    "paligemma-3b",
]


def get_config(arch_id: str) -> ArchConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
