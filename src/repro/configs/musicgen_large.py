"""MusicGen-large: decoder-only over EnCodec tokens [arXiv:2306.05284].

Modality frontend (EnCodec) is a stub per assignment: inputs are already
audio-token ids (single interleaved codebook stream; the release uses 4
codebooks with delay interleaving — noted in DESIGN.md).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    norm="ln", gated_mlp=False, act="gelu", norm_eps=1e-5,
)
