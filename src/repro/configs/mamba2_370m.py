"""Mamba2-370M: attention-free SSD [arXiv:2405.21060]."""
from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMCfg(d_state=128, expand=2, head_dim=64),
    sub_quadratic=True,
)
